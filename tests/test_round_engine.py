"""The compiled round engine (ISSUE 4 tentpole): scan↔loop parity across
strategies and codecs, donation safety, chunking invariance, compile-time
accounting, on-device round inputs, and the buffered arrival loop as
device state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FederatedJob, TaskConfig
from repro.core.round_engine import chunk_plan
from repro.core.session import BufferedScheduler


def _job(**kw):
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=4, batch=2,
                        seq=16, heterogeneity=0.3, seed=0),
        strategy="fedavg", rounds=3, lr=1e-3, seed=0)
    base.update(kw)
    return FederatedJob(**base)


def _assert_trees_close(a, b, rtol=1e-4, atol=1e-6):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Parity: the scan engine vs the retired per-round loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "gcml"])
def test_scan_matches_loop(strategy):
    """Same seed ⇒ same globals AND same per-round losses, with churn:
    the scan consumes the identical masks/pairings/batches, so fusing K
    rounds into one program must not change the math."""
    job = _job(strategy=strategy, max_dropout=1)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params)
    np.testing.assert_allclose(loop.losses, scan.losses, rtol=1e-4)
    if strategy == "gcml":              # pairing history must match too
        for hl, hs in zip(loop.history, scan.history):
            assert hl["partner"] == hs["partner"]
            assert hl["is_receiver"] == hs["is_receiver"]


@pytest.mark.parametrize("strategy", ["pooled", "individual"])
def test_scan_matches_loop_baselines(strategy):
    job = _job(strategy=strategy, rounds=2)
    loop = job.replace(round_engine="loop").run()
    scan = job.run()                    # auto resolves to the scan engine
    _assert_trees_close(loop.global_params, scan.global_params)


def test_scan_matches_loop_compressed_int8():
    """The on-device codec replicates the wire codec's per-leaf chunk
    layout, so quantized-global parity holds at the same tolerance the
    stacked↔thread test uses — and the simulated byte accounting is
    byte-identical."""
    job = _job(compression="int8", rounds=3)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params,
                        rtol=2e-3, atol=1e-4)
    assert scan.comm["upload_bytes"] == loop.comm["upload_bytes"]
    assert scan.comm["upload_raw_bytes"] == loop.comm["upload_raw_bytes"]
    assert scan.comm["upload_raw_bytes"] >= 3 * scan.comm["upload_bytes"]
    assert [h["upload_bytes"] for h in scan.history] == \
        [h["upload_bytes"] for h in loop.history]


def test_scan_matches_loop_compressed_fp8():
    """fp8's e4m3 cast can flip near-tie bins between the numpy and XLA
    converters, so parity is behavioral (per-element within one coarse
    fp8 quantization step), not bitwise like int8."""
    job = _job(compression="fp8", rounds=2)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params,
                        rtol=5e-2, atol=1e-3)


def test_scan_matches_loop_buffered():
    """The traced arrival loop replays the retired loop's order stream,
    discounts and K-of-S finalizations — versions match round for round."""
    job = _job(scheduler=BufferedScheduler(buffer_k=2), rounds=4)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params,
                        rtol=1e-4, atol=1e-5)
    assert [h["version"] for h in loop.history] == \
        [h["version"] for h in scan.history]
    assert all("step_s" in h for h in scan.history)
    assert all("step_s" in h for h in loop.history)   # satellite fix


def test_scan_matches_loop_buffered_int8():
    """Buffered + quantized deltas: the decode-reference ring lives on
    device; the flat chunk layout differs from the per-leaf wire layout,
    so parity is behavioral (close globals, ≥3× byte ratio)."""
    job = _job(scheduler=BufferedScheduler(buffer_k=2), compression="int8",
               rounds=4)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params,
                        rtol=5e-3, atol=5e-4)
    assert scan.comm["upload_count"] == loop.comm["upload_count"]
    assert scan.comm["upload_raw_bytes"] >= 3 * scan.comm["upload_bytes"]


def test_scan_matches_loop_compressed_fedprox():
    """ROADMAP gap closed: the stacked compressed path is no longer
    fedavg-only — fedprox runs its local half (``fedprox-local``) with
    the proximal anchor re-pinned to each broadcast global, on both the
    loop and the scan, with byte-identical accounting."""
    job = _job(strategy="fedprox", compression="int8", rounds=3)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params,
                        rtol=2e-3, atol=1e-4)
    assert scan.comm["upload_bytes"] == loop.comm["upload_bytes"]
    assert scan.comm["compression"] == "int8"


def test_compressed_fedprox_prox_actually_pulls():
    """The proximal term must bite on the compressed path: a large mu
    anchors local training to the broadcast global, so the federation
    drifts less from its initialization than with mu=0."""
    from repro.core import federation as F
    # local_steps > 1: with a single step sites sit exactly at the
    # anchor, where the proximal gradient vanishes
    base = _job(strategy="fedprox", compression="int8", rounds=3,
                local_steps=3, lr=5e-3)
    tight = base.replace(prox_mu=50.0).run()
    loose = base.replace(prox_mu=0.0).run()
    ctx = base.context()
    init = F.global_model(
        F.init_fl_state(ctx, base.task.build().init_fn,
                        jax.random.PRNGKey(base.seed)), ctx)

    def dist(res):
        return float(sum(
            jnp.sum(jnp.square(jnp.asarray(np.asarray(g), jnp.float32)
                               - i.astype(jnp.float32)))
            for g, i in zip(jax.tree.leaves(res.global_params),
                            jax.tree.leaves(init))))
    assert dist(tight) < dist(loose)


def test_topk_fixed_compiles_under_scan():
    """The fixed-k sparsifier runs on the scan engine (the data-shaped
    ``topk-sparse`` still takes the host loop): byte accounting matches
    the wire codec round for round (dense bootstrap, then 8·k per leaf),
    and the run trains."""
    job = _job(compression="topk-fixed", rounds=4, lr=5e-3)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()     # must NOT fall back
    assert [h["upload_bytes"] for h in scan.history] == \
        [h["upload_bytes"] for h in loop.history]
    assert scan.history[1]["upload_bytes"] < scan.history[0]["upload_bytes"]
    assert np.isfinite(scan.losses).all()
    assert scan.final_loss < scan.losses[0]
    # selection ties differ between argpartition and lax.top_k, so
    # parity is behavioral: overwhelmingly-equal globals + equal bytes
    mism = tot = 0
    for x, y in zip(jax.tree.leaves(loop.global_params),
                    jax.tree.leaves(scan.global_params)):
        bad = ~np.isclose(np.asarray(x), np.asarray(y), rtol=5e-2, atol=1e-3)
        mism += int(bad.sum())
        tot += bad.size
    assert mism / tot < 0.01
    # sparse rounds really are sparse: ~10% of entries at 8 B each vs
    # dense fp32 (the run total includes the dense bootstrap round)
    assert scan.history[1]["upload_bytes"] * 4 < scan.history[0]["upload_bytes"]
    assert scan.comm["upload_raw_bytes"] > 2 * scan.comm["upload_bytes"]


def test_topk_sparse_still_falls_back():
    with pytest.raises(ValueError, match="scan"):
        _job(compression="topk-sparse", round_engine="scan").run()


@pytest.mark.parametrize("kind,extra", [
    ("dose", {}), ("seg", {"in_channels": 2, "num_classes": 3})])
def test_device_data_volume_tasks(kind, extra):
    """Satellite: traced jnp dose/seg generators — device_data=True now
    covers the SA-Net tasks, zero per-round host batch generation."""
    job = FederatedJob(
        task=TaskConfig(kind=kind, sites=3, batch=2, volume=(16, 16, 16),
                        heterogeneity=0.3, seed=0, **extra),
        strategy="fedavg", rounds=3, lr=3e-3, seed=0, device_data=True)
    res = job.run()
    assert np.isfinite(res.losses).all()
    assert res.final_loss < res.losses[0]


def test_traced_volume_generators_match_host_shapes():
    from repro.data.synthetic import DoseTaskGenerator, SegTaskGenerator
    dg = DoseTaskGenerator(volume=(8, 8, 8), num_oars=2, num_sites=3,
                           heterogeneity=0.4)
    host = dg.stacked_batches(0, 2, 2)
    dev = dg.traced_stacked_batches(jax.random.PRNGKey(0), 2, 2)
    assert set(host) == set(dev)
    for k in host:
        assert host[k].shape == tuple(dev[k].shape), k
    # the analytic dose law holds on device too: normalized, body-masked
    dose = np.asarray(dev["dose"])
    assert 0.0 <= dose.min() and dose.max() <= 1.0 + 1e-6
    assert (np.asarray(dev["mask"]) == np.asarray(host["mask"])).all()
    sg = SegTaskGenerator(volume=(8, 8, 8), in_channels=2, num_classes=3,
                          num_sites=2)
    hs = sg.stacked_batches(0, 1, 2)
    ds = sg.traced_stacked_batches(jax.random.PRNGKey(1), 1, 2)
    for k in hs:
        assert hs[k].shape == tuple(ds[k].shape), k
    labs = np.asarray(ds["labels"])
    assert labs.min() >= 0 and labs.max() < 3 and labs.dtype == np.int32


def test_scan_matches_loop_dose_task():
    """Volume tasks have no traced generator — host-generated batches
    still ride the compiled scan, chunk-transferred."""
    job = FederatedJob(
        task=TaskConfig(kind="dose", sites=3, batch=2, volume=(16, 16, 16),
                        heterogeneity=0.3, seed=0),
        strategy="fedavg", rounds=2, seed=0)
    loop = job.replace(round_engine="loop").run()
    scan = job.run()
    _assert_trees_close(loop.global_params, scan.global_params)


# ---------------------------------------------------------------------------
# Chunking, donation, compile accounting
# ---------------------------------------------------------------------------


def test_chunking_invariance():
    """Chunk size is an execution knob, not a semantic one."""
    job = _job(rounds=5)
    ref = job.replace(chunk_rounds=5).run()
    for ck in (1, 2, 3):
        res = job.replace(chunk_rounds=ck).run()
        _assert_trees_close(ref.global_params, res.global_params)
        np.testing.assert_allclose(ref.losses, res.losses, rtol=1e-5)


def test_chunk_plan_alignment():
    assert chunk_plan(20, 8) == [8, 8, 4]
    assert chunk_plan(3, None) == [3]
    assert sum(chunk_plan(100, None)) == 100
    # with checkpointing every 10 rounds, a boundary follows rounds 0/10
    plan = chunk_plan(20, 8, ckpt_every=10)
    ends = np.cumsum(plan)
    assert 1 in ends and 11 in ends and ends[-1] == 20


def test_no_use_after_donate():
    """The carry is donated into every chunk; the returned state must be
    the live one (readable, reusable) even after multiple chunks."""
    job = _job(rounds=4, chunk_rounds=2)
    res = job.run()
    assert res.state is not None
    for leaf in jax.tree.leaves(res.state["params"]):
        assert np.isfinite(np.asarray(leaf)).all()
    # the recorded global equals the state's aggregate (nothing stale)
    from repro.core import federation as F
    ctx = job.context()
    _assert_trees_close(res.global_params, F.global_model(res.state, ctx))


def test_compile_time_reported_separately():
    """Satellite: round 0's step_s no longer absorbs jit compilation —
    on both engines compile_s is reported on the JobResult and step_s
    stays in steady-state range."""
    for engine in ("scan", "loop"):
        res = _job(rounds=3, round_engine=engine).run()
        assert res.compile_s > 0.0
        steps = [h["step_s"] for h in res.history]
        assert max(steps) < res.compile_s      # compile dwarfs a tiny step
        assert res.to_dict()["compile_s"] == res.compile_s


def test_checkpointing_on_scan_engine(tmp_path):
    job = _job(rounds=4, chunk_rounds=4, ckpt_every=2,
               checkpoint_dir=str(tmp_path))
    res = job.run()
    assert np.isfinite(res.final_loss)
    saved = sorted(p.name for p in tmp_path.glob("global_round*.npz"))
    assert saved                        # rounds 0 and 2 materialized
    assert (tmp_path / "manifest.json").exists()


# ---------------------------------------------------------------------------
# On-device round inputs (traced masks / pairings / batches)
# ---------------------------------------------------------------------------


def test_device_data_trains():
    job = _job(rounds=6, lr=5e-3, device_data=True)
    res = job.run()
    assert np.isfinite(res.losses).all()
    assert res.final_loss < res.losses[0]
    assert res.comm["upload_count"] == 6 * 4    # all sites active


def test_device_data_with_churn_and_gossip():
    # odd site count: the traced pairing must leave one site out cleanly
    job = _job(task=TaskConfig(kind="tokens", arch="smollm-135m", sites=5,
                               batch=2, seq=16, heterogeneity=0.3, seed=0),
               strategy="gcml", rounds=4, max_dropout=2, device_data=True)
    res = job.run()
    assert np.isfinite(np.asarray(res.losses)).all()
    for h in res.history:
        assert 3 <= h["active"] <= 5            # S − N_max bound holds
        # receivers always have a distinct partner assigned
        for i, is_r in enumerate(h["is_receiver"]):
            if is_r:
                assert h["partner"][i] != i


def test_device_data_unsupported_combos_raise():
    with pytest.raises(ValueError, match="device_data"):
        _job(device_data=True, compression="int8").run()
    with pytest.raises(ValueError, match="device_data"):
        _job(device_data=True, scheduler=BufferedScheduler(buffer_k=2)).run()
    # volume tasks now have traced generators — EXCEPT with site_pools,
    # whose case recycling indexes by host step
    with pytest.raises(ValueError, match="device_data"):
        FederatedJob(task=TaskConfig(kind="dose", sites=2, batch=1,
                                     volume=(16, 16, 16),
                                     site_pools=(2, 2)),
                     rounds=1, device_data=True).run()
    # pod-tier churn needs the host-precomputed schedule
    with pytest.raises(ValueError, match="pod_dropout"):
        _job(device_data=True, topology="pods:2", pod_dropout=1).run()


@pytest.mark.parametrize("sites", [5, 6])   # odd counts sit one site out
def test_traced_round_inputs_laws(sites):
    """Traced Algorithm-2 churn and gossip pairing respect the host
    invariants: dropout bounded by N_max, pairings are disjoint
    sender/receiver sets among active sites."""
    from repro.core.dropout import availability_step_traced
    from repro.core.gossip import pair_sites_traced
    key = jax.random.PRNGKey(0)
    active = jnp.ones((sites,), bool)
    for r in range(30):
        active = availability_step_traced(jax.random.fold_in(key, r),
                                          active, 2)
        a = np.asarray(active)
        assert sites - 2 <= a.sum() <= sites
    for r in range(10):
        k = jax.random.fold_in(key, 100 + r)
        partner, is_recv, is_send = (np.asarray(x) for x in
                                     pair_sites_traced(k, active))
        a = np.asarray(active)
        assert not (is_recv & is_send).any()
        assert is_recv.sum() == is_send.sum() <= a.sum() // 2
        assert (a[partner[is_recv]]).all()      # senders are active
        assert set(partner[is_recv]) == set(np.flatnonzero(is_send))


# ---------------------------------------------------------------------------
# Engine selection surface
# ---------------------------------------------------------------------------


def test_round_engine_scan_raises_on_unsupported():
    with pytest.raises(ValueError, match="scan"):
        _job(compression="topk-sparse", round_engine="scan").run()


def test_round_engine_unknown_name():
    with pytest.raises(ValueError, match="round_engine"):
        _job(round_engine="bogus").run()


def test_topk_and_wide_staleness_fall_back_to_loop():
    res = _job(compression="topk-sparse", rounds=2).run()
    assert np.isfinite(res.final_loss)
    sched = BufferedScheduler(buffer_k=2, max_staleness=64)
    res = _job(scheduler=sched, compression="int8", rounds=2).run()
    assert np.isfinite(res.final_loss)


def test_train_cli_chunk_rounds_flag():
    from repro.launch.train import make_parser
    args = make_parser().parse_args(["--chunk-rounds", "4"])
    assert args.chunk_rounds == 4
    assert args.round_engine == "auto"
    assert make_parser().parse_args([]).chunk_rounds is None


# ---------------------------------------------------------------------------
# Sharded stacked simulator (ISSUE 8 tentpole): the [S, …] site state
# partitioned over the ("site",) mesh must reproduce the dense engine.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw,rtol", [
    (dict(), 1e-5),                                      # fedavg
    (dict(strategy="fedprox"), 1e-5),                    # proximal anchor
    (dict(topology="pods:2"), 1e-5),                     # two-tier fold
    (dict(compression="int8"), 1e-4),                    # qdq + EF residual
    (dict(compression="int8", strategy="fedprox"), 1e-4),
    (dict(sample="uniform:2", dropout_scenario="shutdown"), 1e-5),
    (dict(sample="poisson:0.6", max_dropout=1,
          dropout_scenario="shutdown"), 1e-5),
], ids=["fedavg", "fedprox", "pods", "int8", "int8-fedprox",
        "sampled-uniform", "sampled-poisson-churn"])
def test_sharded_matches_dense(kw, rtol):
    """On a 1-device mesh the sharded engine is a pure re-layout of the
    dense scan — global params, per-round losses and the final state all
    agree (int8 at the quantization tolerance)."""
    job = _job(rounds=4, **kw)
    dense = job.run()
    shard = job.replace(shard_sites=True).run()
    _assert_trees_close(dense.global_params, shard.global_params,
                        rtol=rtol, atol=10 * rtol)
    assert shard.comm["sharded"] is True
    assert shard.comm["devices"] >= 1
    assert shard.comm["upload_bytes"] == dense.comm["upload_bytes"]
    # loss parity on participant rows: the dense engine also evaluates
    # (frozen) non-participants, the sharded engine never materializes
    # them (NaN rows) — so compare where the sharded engine trained
    for hd, hs in zip(dense.history, shard.history):
        assert hd["active"] == hs["active"]
        d = np.asarray(hd["per_site_loss"])
        s = np.asarray(hs["per_site_loss"])
        m = np.isfinite(s)
        assert m.sum() == hs["active"]
        np.testing.assert_allclose(d[m], s[m], rtol=1e-4)


def test_sharded_per_site_losses_match_dense():
    """Full participation: every site's loss trajectory is reproduced
    row for row, not just the round mean."""
    job = _job(rounds=3)
    dense = job.run()
    shard = job.replace(shard_sites=True).run()
    for hd, hs in zip(dense.history, shard.history):
        np.testing.assert_allclose(hd["per_site_loss"], hs["per_site_loss"],
                                   rtol=1e-4)


def test_sharded_state_live_after_donation():
    """The carry is donated into every compiled step; the returned state
    must be the live copy — readable, finite, and [S]-shaped — and a
    second identical run must reproduce it exactly (nothing aliased)."""
    job = _job(rounds=3, shard_sites=True)
    a = job.run()
    assert a.state is not None
    for leaf in jax.tree.leaves(a.state["params"]):
        arr = np.asarray(leaf)
        assert arr.shape[0] == 4 and np.isfinite(arr).all()
    b = job.run()
    _assert_trees_close(a.state["params"], b.state["params"], rtol=0)
    _assert_trees_close(a.global_params, b.global_params, rtol=0)


def test_sharded_records_participants_per_round():
    res = _job(rounds=3, shard_sites=True, sample="uniform:2",
               dropout_scenario="shutdown").run()
    for h in res.history:
        assert h["active"] == 2
        assert h["participants"] == 2
        assert h["k_cap"] >= 2


def test_sharded_unsupported_combos_raise():
    with pytest.raises(ValueError, match="shard"):
        _job(shard_sites=True, scheduler=BufferedScheduler(buffer_k=2)).run()
    with pytest.raises(ValueError, match="shard"):
        _job(shard_sites=True, strategy="gcml").run()
    with pytest.raises(ValueError, match="shard"):
        _job(shard_sites=True, compression="fp8").run()
    with pytest.raises(ValueError, match="shard"):
        _job(shard_sites=True, device_data=True).run()
    with pytest.raises(ValueError, match="shard"):
        _job(shard_sites=True, dp_clip=1.0, dp_noise_multiplier=1.0).run()
    with pytest.raises(ValueError, match="shard"):
        _job(shard_sites=True, transport="thread").run()
    # thinned participation without deterministic shutdown re-entry
    with pytest.raises(ValueError, match="shutdown"):
        _job(shard_sites=True, sample="uniform:2",
             dropout_scenario="disconnect").run()
