"""The unified FederatedJob API: transport parity (stacked ↔ TCP stack),
the sync/buffered scheduler seam, and the satellite fixes riding along
(stale-upload rejection, MeshConfig.for_sites)."""
import jax
import numpy as np
import pytest

from repro.api import (FederatedJob, StackedTransport, TaskConfig,
                       ThreadTransport, TcpTransport, resolve_transport)
from repro.comms.coordinator import AggregationServer
from repro.comms.peer import Peer
from repro.configs.base import MeshConfig
from repro.core.session import (BufferedScheduler, SyncScheduler,
                                availability_masks, resolve_scheduler)


def _token_job(**kw):
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=4, batch=4,
                        seq=32, heterogeneity=0.3, seed=0),
        strategy="fedavg", rounds=3, lr=1e-3, seed=0)
    base.update(kw)
    return FederatedJob(**base)


def _assert_trees_close(a, b, rtol=2e-3, atol=1e-4):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Scheduler seam units
# ---------------------------------------------------------------------------


def test_sync_scheduler_barrier_semantics():
    s = SyncScheduler()
    assert s.discount(0) == 1.0
    assert s.discount(1) is None                 # straggler rejected
    assert s.discount(-1) is None
    assert not s.ready(3, 4)
    assert s.ready(4, 4)


def test_buffered_scheduler_k_of_s_trigger():
    b = BufferedScheduler(buffer_k=2)
    assert not b.ready(1, 4)
    assert b.ready(2, 4)                         # K of S
    assert b.ready(1, 1)                         # clamped to active count


def test_buffered_staleness_weights_sum_to_one_and_decrease():
    b = BufferedScheduler(buffer_k=2, alpha=0.5)
    w = b.staleness_weights([0, 1, 3])
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    assert w[0] > w[1] > w[2]                    # staler ⇒ lighter


def test_buffered_scheduler_rejects_too_stale():
    b = BufferedScheduler(buffer_k=2, max_staleness=2)
    assert b.discount(2) is not None
    assert b.discount(3) is None
    assert b.discount(-1) is None
    with pytest.raises(ValueError, match="staleness"):
        b.staleness_weights([0, 5])


def test_resolvers():
    assert isinstance(resolve_scheduler("sync"), SyncScheduler)
    assert isinstance(resolve_scheduler("buffered"), BufferedScheduler)
    assert isinstance(resolve_transport("stacked"), StackedTransport)
    assert isinstance(resolve_transport("thread"), ThreadTransport)
    assert isinstance(resolve_transport("tcp"), TcpTransport)
    with pytest.raises(KeyError):
        resolve_scheduler("bogus")
    with pytest.raises(KeyError):
        resolve_transport("bogus")


def test_availability_masks_deterministic():
    a = availability_masks(5, 2, seed=7, rounds=20)
    b = availability_masks(5, 2, seed=7, rounds=20)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (20, 5)
    assert (a.sum(axis=1) >= 3).all()            # never below S - N_max


# ---------------------------------------------------------------------------
# Aggregation-server scheduling (satellite: stale-upload rejection)
# ---------------------------------------------------------------------------


def test_server_rejects_stale_round_upload():
    """A straggler's round r−1 upload must NOT fold into round r."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=2)
    p0, p1 = Peer(0), Peer(1)
    try:
        # server is collecting round 1; an upload tagged round 0 is stale
        ack = p0.upload(agg.addr, {"w": np.full(3, 99.0, np.float32)}, 0)
        assert ack["stale"] is True
        ack = p0.upload(agg.addr, {"w": np.full(3, 2.0, np.float32)}, 1)
        assert ack["stale"] is False
        p1.upload(agg.addr, {"w": np.full(3, 4.0, np.float32)}, 1)
        g = p0.download(agg.addr, 1)
        np.testing.assert_allclose(g["w"], 3.0, rtol=1e-6)   # 99.0 never folded
    finally:
        p0.close()
        p1.close()
        agg.stop()


def test_server_buffered_scheduler_aggregates_after_k():
    agg = AggregationServer("127.0.0.1", 0, num_sites=3,
                            scheduler=BufferedScheduler(buffer_k=2))
    peers = [Peer(i) for i in range(3)]
    try:
        peers[0].upload(agg.addr, {"w": np.full(2, 3.0, np.float32)}, 1)
        ack = peers[1].upload(agg.addr, {"w": np.full(2, 9.0, np.float32)}, 1)
        assert ack["round"] == 1                 # K=2 reached → new global
        g = peers[0].download(agg.addr, 1)
        np.testing.assert_allclose(g["w"], 6.0, rtol=1e-6)
        # the third (now stale-by-1) upload is admitted, discounted, and
        # starts the next buffer instead of being dropped
        ack = peers[2].upload(agg.addr, {"w": np.full(2, 1.0, np.float32)}, 1)
        assert ack["stale"] is False and ack["round"] == 1
        _, meta, _ = peers[0]._channel(agg.addr).request("status", {}, None)
        assert meta["pending"] == 1
    finally:
        for p in peers:
            p.close()
        agg.stop()


# ---------------------------------------------------------------------------
# Transport parity: same seed ⇒ same global model
# ---------------------------------------------------------------------------


def test_stacked_vs_tcp_stack_parity_fedavg():
    """Same seed ⇒ the vmapped simulator and the real TCP round trips
    (Peer/AggregationServer driven per site) agree after 3 FedAvg rounds."""
    stacked = _token_job().run()
    threaded = _token_job(transport="thread").run()
    assert threaded.transport == "thread"
    _assert_trees_close(stacked.global_params, threaded.global_params)
    np.testing.assert_allclose(stacked.losses, threaded.losses, rtol=1e-4)


def test_tcp_process_transport_parity():
    """One OS process per site over real TCP matches the simulator."""
    job = _token_job(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=2, batch=2,
                        seq=16, seed=0),
        rounds=2)
    stacked = job.run()
    tcp = job.replace(transport="tcp").run()
    _assert_trees_close(stacked.global_params, tcp.global_params)


def test_socket_transport_rejects_pooled():
    with pytest.raises(ValueError, match="pooled"):
        _token_job(strategy="pooled", transport="thread").run()


def test_buffered_over_tcp_stack_no_staleness_runaway():
    """Under a buffered scheduler the server finalizes ~S/K times per
    site round, so sites must anchor upload staleness to the global they
    last pulled — a loop-round tag would drift past max_staleness and
    get every later upload rejected (regression)."""
    # with loop-round tags, staleness grows ~(S/K − 1) per round: here it
    # passes max_staleness=6 around round 8 and every later upload from
    # every site is rejected (≥ 8 rejections by round 9, permanently);
    # with base-round anchoring it stays ≤ ~2 apart from rare thread-skew
    rounds, sites = 9, 4
    res = _token_job(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=sites,
                        batch=2, seq=16, seed=0),
        rounds=rounds, transport="thread",
        scheduler=BufferedScheduler(buffer_k=2, max_staleness=6)).run()
    assert np.isfinite(res.losses).all()
    assert sum(res.history[-1]["stale_uploads"]) <= sites


def test_socket_transport_checkpoints_and_times_the_run(tmp_path):
    """--checkpoint must not be a silent no-op on socket transports (the
    final global is saved), and wall_s must span the actual run."""
    job = _token_job(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=2, batch=2,
                        seq=16, seed=0),
        rounds=2, transport="thread", checkpoint_dir=str(tmp_path))
    res = job.run()
    assert res.wall_s > 0.5                      # not the post-hoc ~0 bug
    assert res.history[0]["wall_s"] > 0.1        # run-mean per round
    assert (tmp_path / "manifest.json").exists()
    assert list(tmp_path.glob("global_round*.npz"))


def test_coordinator_serves_lagging_round_assignment():
    """A site asking for round r must get round r's pairing even after a
    faster site already pulled round r+1 (regression: the coordinator
    used to overwrite its single stored assignment)."""
    from repro.comms.coordinator import CoordinationServer
    coord = CoordinationServer("127.0.0.1", 0, num_sites=3, seed=3)
    peers = [Peer(i) for i in range(3)]
    try:
        for p in peers:
            p.register(coord.addr)
        asg1 = peers[0].get_assignment(coord.addr, 1)
        asg2 = peers[0].get_assignment(coord.addr, 2)
        assert asg2["round"] == 2
        lagged = peers[1].get_assignment(coord.addr, 1)
        assert lagged["round"] == 1
        assert lagged["partner"] == asg1["partner"]
        assert lagged["is_receiver"] == asg1["is_receiver"]
    finally:
        for p in peers:
            p.close()
        coord.stop()


# ---------------------------------------------------------------------------
# Buffered-async end to end (stacked simulator)
# ---------------------------------------------------------------------------


def test_buffered_async_tracks_sync_fedavg():
    """FedBuff-style K<S buffered rounds land within 10% of sync FedAvg
    on the reduced token task (ROADMAP's async open item)."""
    rounds = 6
    sync = _token_job(rounds=rounds, lr=5e-3).run()
    buf = _token_job(rounds=rounds, lr=5e-3,
                     scheduler=BufferedScheduler(buffer_k=2)).run()
    assert buf.scheduler == "buffered"
    assert sync.final_loss < sync.losses[0]          # both actually train
    assert buf.final_loss < buf.losses[0]
    assert abs(buf.final_loss - sync.final_loss) <= 0.1 * sync.final_loss
    # versions advanced faster than rounds (K=2 of 4 ⇒ ~2 per round)
    assert buf.history[-1]["version"] >= rounds


def test_buffered_requires_fedavg():
    with pytest.raises(ValueError, match="fedavg"):
        _token_job(strategy="fedprox", scheduler="buffered").run()


# ---------------------------------------------------------------------------
# Satellites: MeshConfig.for_sites, job surface
# ---------------------------------------------------------------------------


def test_mesh_for_sites_hoists_fsdp_arithmetic():
    m = MeshConfig.for_sites(8)
    assert (m.sites_per_pod, m.fsdp, m.data_axis_size) == (8, 2, 16)
    m = MeshConfig.for_sites(16)
    assert (m.fsdp, m.data_axis_size) == (1, 16)
    m = MeshConfig.for_sites(3)                  # 16 % 3 != 0 → unsharded
    assert (m.fsdp, m.data_axis_size) == (1, 3)


def test_train_cli_has_quiet_not_verbose():
    from repro.launch.train import make_parser
    args = make_parser().parse_args([])
    assert args.quiet is False                   # progress on by default
    assert not hasattr(args, "verbose")          # old broken flag is gone
    assert make_parser().parse_args(["--quiet"]).quiet is True


def test_job_result_shape():
    res = _token_job(rounds=2).run()
    assert len(res.history) == 2
    assert {"round", "loss", "active", "per_site_loss", "wall_s"} <= \
        set(res.history[0])
    d = res.to_dict()
    assert np.isfinite(d["final_loss"])
    assert d["transport"] == "stacked" and d["scheduler"] == "sync"
