"""Metrics, checkpointing, optimizers, schedules, registry coverage."""
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore, load_pytree, save_pytree
from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ALIASES, ARCH_IDS, get_arch, is_skipped
from repro.metrics import dice_coefficient, dose_score, dvh_score, one_way_anova
from repro.optim import adamw, apply_updates, clip_by_global_norm, sgd
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine


def test_registry_covers_all_assigned_archs():
    assert len([a for a in ARCH_IDS if a != "sanet_openkbp"]) == 10
    for alias in ["deepseek-v2-236b", "rwkv6-7b", "jamba-1.5-large-398b",
                  "qwen3-8b", "qwen3-moe-30b-a3b", "chameleon-34b", "gemma3-1b",
                  "smollm-135m", "granite-3-2b", "musicgen-medium"]:
        mod = get_arch(alias)
        assert mod.CONFIG.source, alias
        assert callable(mod.reduced) and callable(mod.mesh_for)


def test_skip_matrix_documented():
    # long_500k runs ONLY for sub-quadratic archs
    runners = [a for a in ARCH_IDS if a != "sanet_openkbp"
               and not is_skipped(a, "long_500k")]
    assert sorted(runners) == ["gemma3_1b", "jamba_1p5_large_398b", "rwkv6_7b"]
    for a in ARCH_IDS:
        for s in INPUT_SHAPES:
            r = is_skipped(a, s)
            assert r is None or isinstance(r, str)


def test_dose_and_dvh_scores():
    rng = np.random.default_rng(0)
    true = rng.uniform(0, 70, (8, 8, 8))
    mask = np.ones_like(true)
    assert dose_score(true, true, mask) == 0.0
    assert dose_score(true + 1.0, true, mask) == pytest.approx(1.0)
    roi = np.zeros_like(true)
    roi[2:5, 2:5, 2:5] = 1
    assert dvh_score(true, true, [roi]) == 0.0
    assert dvh_score(true + 2.0, true, [roi]) == pytest.approx(2.0, rel=1e-6)


def test_dice():
    a = np.zeros((4, 4, 4), int)
    b = np.zeros((4, 4, 4), int)
    a[:2] = 1
    b[:2] = 1
    assert dice_coefficient(a, b, 2) == 1.0
    b[:] = 0
    b[2:] = 1
    assert dice_coefficient(a, b, 2) == 0.0


def test_anova_null_and_effect():
    rng = np.random.default_rng(1)
    same = [rng.normal(0.9, 0.05, 40) for _ in range(5)]
    f, p = one_way_anova(same)
    assert p > 0.01
    diff = [rng.normal(0.9 - 0.1 * i, 0.02, 40) for i in range(5)]
    f2, p2 = one_way_anova(diff)
    assert p2 < 1e-9 and f2 > f


def test_adamw_and_sgd_descend_quadratic():
    for opt, steps in [(adamw(0.1), 60), (sgd(0.05, momentum=0.9), 150)]:
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(steps):
            grads = {"w": 2 * params["w"]}
            updates, state = opt.update(grads, state, params)
            params = apply_updates(params, updates)
        assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip():
    tree = {"a": jnp.array([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert norm == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    cos = cosine_schedule(1.0, 100)
    assert float(cos(jnp.array(0))) == pytest.approx(1.0)
    assert float(cos(jnp.array(100))) == pytest.approx(0.1, rel=1e-5)
    warm = linear_warmup_cosine(1.0, 10, 110)
    assert float(warm(jnp.array(5))) == pytest.approx(0.5)


def test_checkpoint_store_retention():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(pathlib.Path(d), keep=2)
        tree = {"w": jnp.arange(4.0)}
        for r in range(5):
            store.save("global", r, jax.tree.map(lambda x: x + r, tree))
        files = list(pathlib.Path(d).glob("global_*.npz"))
        assert len(files) == 2
        back, rnd = store.latest("global", tree)
        assert rnd == 4
        np.testing.assert_allclose(np.asarray(back["w"]), np.arange(4.0) + 4)


def test_save_load_roundtrip_nested():
    with tempfile.TemporaryDirectory() as d:
        p = pathlib.Path(d) / "x.npz"
        tree = {"a": jnp.ones((2, 3)), "list": [jnp.zeros(2), {"c": jnp.array(7)}]}
        save_pytree(p, tree)
        back = load_pytree(p, tree)
        np.testing.assert_allclose(np.asarray(back["list"][1]["c"]), 7)
