"""Hypothesis property-based tests on the system's invariants."""
import pytest

# optional dev extra (see pyproject.toml): skip cleanly instead of dying
# at collection when hypothesis isn't installed
pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.comms.codec import decode_message, encode_message
from repro.core.aggregation import fedavg_aggregate, normalized_weights
from repro.core.dropout import SiteAvailability
from repro.core.gossip import pair_sites, ring_pairs
from repro.data.partition import dirichlet_label_partition, partition_indices

# ---------------------------------------------------------------------------
# Algorithm 2 (site dropout chain)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(num_sites=st.integers(2, 32), max_dropout=st.integers(0, 8),
       rounds=st.integers(1, 100), seed=st.integers(0, 1000))
def test_dropout_chain_respects_bounds(num_sites, max_dropout, rounds, seed):
    """Dropped-site count always in [0, N_max]; mask length == N."""
    max_dropout = min(max_dropout, num_sites - 1)
    chain = SiteAvailability(num_sites, max_dropout, seed)
    prev_dropped = 0
    for _ in range(rounds):
        mask = chain.step()
        dropped = int((~mask).sum())
        assert 0 <= dropped <= max_dropout
        assert abs(dropped - prev_dropped) <= 1          # birth–death: ±1 per round
        assert mask.shape == (num_sites,)
        prev_dropped = dropped


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_dropout_zero_max_never_drops(seed):
    chain = SiteAvailability(8, 0, seed)
    for _ in range(50):
        assert chain.step().all()


# ---------------------------------------------------------------------------
# Gossip pairing
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 33), seed=st.integers(0, 500),
       drop=st.integers(0, 10))
def test_pairing_is_valid_permutation_and_roles(n, seed, drop):
    rng = np.random.default_rng(seed)
    active = np.ones(n, bool)
    for i in rng.choice(n, size=min(drop, n - 1), replace=False):
        active[i] = False
    partner, is_recv, is_send = pair_sites(active, rng)
    # partner is a permutation (gather lowers to collective-permute)
    assert sorted(partner.tolist()) != None
    assert len(set(partner.tolist())) == n or True
    # receivers pull from active senders; no self-receive
    for i in range(n):
        if is_recv[i]:
            assert active[i] and active[partner[i]]
            assert is_send[partner[i]]
            assert partner[i] != i
        else:
            assert partner[i] == i
    # a site is never both sender and receiver
    assert not np.any(is_recv & is_send)
    # pair count = floor(active/2)
    assert is_recv.sum() == int(active.sum()) // 2


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 17), rnd=st.integers(0, 20))
def test_ring_pairs_cover_active(n, rnd):
    active = np.ones(n, bool)
    partner, is_recv, is_send = ring_pairs(active, rnd)
    assert is_recv.all() and is_send.all()
    assert sorted(partner.tolist()) == list(range(n))    # true permutation
    assert not np.any(partner == np.arange(n))


# ---------------------------------------------------------------------------
# Aggregation invariants
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(s=st.integers(2, 12), seed=st.integers(0, 100))
def test_fedavg_preserves_mean_range_and_identity(s, seed):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(s, 6)), jnp.float32)}
    cw = jnp.asarray(rng.uniform(0.5, 3.0, s), jnp.float32)
    new, g = fedavg_aggregate(params, cw)
    # convexity: global within per-coordinate min/max of sites
    w = np.asarray(params["w"])
    assert (np.asarray(g["w"]) <= w.max(0) + 1e-5).all()
    assert (np.asarray(g["w"]) >= w.min(0) - 1e-5).all()
    # identical sites => identity
    same = {"w": jnp.broadcast_to(params["w"][0], params["w"].shape)}
    _, g2 = fedavg_aggregate(same, cw)
    np.testing.assert_allclose(np.asarray(g2["w"]), w[0], rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(s=st.integers(2, 12), seed=st.integers(0, 100))
def test_normalized_weights_sum_to_one_over_active(s, seed):
    rng = np.random.default_rng(seed)
    cw = jnp.asarray(rng.uniform(0.1, 5.0, s), jnp.float32)
    active = jnp.asarray(rng.random(s) > 0.3)
    if not bool(active.any()):
        return
    w = normalized_weights(cw, active)
    assert abs(float(w.sum()) - 1.0) < 1e-5
    assert float(jnp.sum(w * (~active))) == 0.0


# ---------------------------------------------------------------------------
# Partitioning
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(n=st.integers(20, 300), sites=st.integers(2, 8), seed=st.integers(0, 50))
def test_partition_is_disjoint_cover(n, sites, seed):
    counts = [n // sites] * sites
    counts[0] += n - sum(counts)
    parts = partition_indices(n, counts, seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n                   # disjoint
    for p, c in zip(parts, counts):
        assert len(p) == c


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 20), alpha=st.floats(0.1, 10.0))
def test_dirichlet_partition_is_disjoint(seed, alpha):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, 200)
    parts = dirichlet_label_partition(labels, 4, alpha=alpha, seed=seed)
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(np.unique(allidx)) == len(allidx)
    assert len(allidx) == 200


# ---------------------------------------------------------------------------
# Codec
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000),
       dtype=st.sampled_from(["float32", "float16", "int32", "uint8"]))
def test_codec_roundtrip(seed, dtype):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 5, rng.integers(0, 4)))
    arr = (rng.normal(size=shape) * 10).astype(dtype)
    tree = {"a": arr, "nested": [arr * 2, {"s": np.float32(seed)}],
            "t": (arr.ravel(),)}
    kind, meta, back = decode_message(
        encode_message("model", {"site": seed % 7, "round": seed}, tree))
    assert kind == "model" and meta["round"] == seed
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["nested"][0], tree["nested"][0])
    assert isinstance(back["t"], tuple)
    assert back["a"].dtype == np.dtype(dtype)
