"""README snippets must not drift from the real surfaces.

Every bash line in the README that invokes ``repro.launch.train`` is
parsed by the *actual* CLI parser and resolved through the job's
``--dry-run`` path (task construction + transport/scheduler/codec
resolution, no training); every python snippet is AST-checked so its
``FederatedJob`` / ``TaskConfig`` / ``replace`` keyword arguments are
real dataclass fields and its ``from x import y`` statements resolve.
CI runs this file on its own in the examples-smoke job, and it rides in
tier-1 locally.
"""
import ast
import dataclasses
import re
import shlex
from pathlib import Path

README = Path(__file__).resolve().parents[1] / "README.md"


def _code_blocks(lang: str):
    return re.findall(rf"```{lang}\n(.*?)```", README.read_text(), flags=re.S)


def test_readme_documents_current_cli_flags():
    text = README.read_text()
    for flag in ["--transport", "--scheduler", "--compression", "--quiet",
                 "--dry-run"]:
        assert flag in text, f"README no longer documents {flag}"


def test_readme_train_cli_lines_resolve_with_dry_run(tmp_path):
    from repro.launch.train import make_parser, run
    cmds = []
    for block in _code_blocks("bash"):
        for line in block.replace("\\\n", " ").splitlines():
            line = line.strip()
            if "repro.launch.train" in line:
                cmds.append(line)
    assert cmds, "README lost its train-CLI examples"
    for cmd in cmds:
        argv = shlex.split(cmd, comments=True)
        while "=" in argv[0]:                # drop env assignments
            argv.pop(0)
        assert argv[:3] == ["python", "-m", "repro.launch.train"], cmd
        # unknown/renamed flags raise SystemExit here — the drift signal
        args = make_parser().parse_args(
            argv[3:] + ["--dry-run", "--out", str(tmp_path)])
        result = run(args)
        assert result["dry_run"] is True, cmd


def test_readme_python_snippets_use_real_api():
    from repro.api import FederatedJob, TaskConfig
    job_fields = {f.name for f in dataclasses.fields(FederatedJob)}
    task_fields = {f.name for f in dataclasses.fields(TaskConfig)}
    blocks = _code_blocks("python")
    assert blocks, "README lost its python examples"
    saw_job = False
    for block in blocks:
        tree = ast.parse(block)              # snippet must compile
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                mod = __import__(node.module,
                                 fromlist=[n.name for n in node.names])
                for n in node.names:
                    assert hasattr(mod, n.name), \
                        f"README imports missing name {n.name} from {node.module}"
            if not isinstance(node, ast.Call):
                continue
            fname = (node.func.id if isinstance(node.func, ast.Name)
                     else getattr(node.func, "attr", None))
            kwargs = {kw.arg for kw in node.keywords if kw.arg}
            if fname == "FederatedJob":
                saw_job = True
                assert kwargs <= job_fields, kwargs - job_fields
            elif fname == "TaskConfig":
                assert kwargs <= task_fields, kwargs - task_fields
            elif fname == "replace":
                assert kwargs <= job_fields, kwargs - job_fields
    assert saw_job


def test_architecture_doc_names_real_modules():
    doc = (README.parent / "docs" / "architecture.md").read_text()
    root = README.parent
    for path in re.findall(r"`(src/repro/[\w/]+\.py)`", doc):
        assert (root / path).exists(), f"docs/architecture.md names missing {path}"