"""Launch-layer units: partition-spec engine, HLO analyzer, shard hints,
mesh configs — all pure/fast (no 512-device lowering here; that's the
dry-run's job)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import MeshConfig
from repro.launch import hlo_analysis as H
from repro.launch import sharding as sh
from repro.models import shardhints


def _fake_mesh(s=2, f=2, m=2):
    """A Mesh over the single CPU device repeated is not allowed; build an
    abstract mesh via mesh_utils-like reshape of the one device — instead
    use jax.sharding.AbstractMesh for spec-only tests."""
    from jax.sharding import AbstractMesh
    names = ("site", "fsdp", "model")
    try:
        # newer jax: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, (s, f, m))))
    except TypeError:
        # older jax: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh((s, f, m), names)


def test_pick_respects_divisibility_and_uniqueness():
    mesh = _fake_mesh(2, 2, 2)
    # 6 not divisible by 4 -> falls through to single axis or None
    spec = sh.pick(mesh, (6, 8), [[("site", "fsdp"), "site", None],
                                  ["model", None]])
    assert spec == P("site", "model")
    # same axis never used twice
    spec = sh.pick(mesh, (4, 4), [["model", None], ["model", None]])
    assert spec == P("model", None)


def test_param_spec_rules():
    mesh = _fake_mesh(2, 2, 2)
    leaf = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    mk = lambda name: (jax.tree_util.DictKey(name),)
    # column-parallel: (fsdp, model)
    assert sh.param_spec(mesh, mk("wq"), leaf, 0) == P("fsdp", "model")
    # row-parallel: (model, fsdp)
    assert sh.param_spec(mesh, mk("wo"), leaf, 0) == P("model", "fsdp")
    # embeddings: vocab over model
    assert sh.param_spec(mesh, mk("embed"), leaf, 0) == P("model", "fsdp")
    # replicated small factors
    assert sh.param_spec(mesh, mk("router"), leaf, 0) == P(None, None)
    # experts [E, D, F]: expert-parallel over model
    e_leaf = jax.ShapeDtypeStruct((8, 64, 32), jnp.float32)
    path = (jax.tree_util.DictKey("ffn"), jax.tree_util.DictKey("w_gate"))
    assert sh.param_spec(mesh, path, e_leaf, 0) == P("model", "fsdp", None)


def test_param_spec_leading_axes():
    mesh = _fake_mesh(2, 2, 2)
    # site-stacked + scan-repeat leading dims: (site, None, fsdp, model)
    leaf = jax.ShapeDtypeStruct((2, 5, 64, 128), jnp.float32)
    path = (jax.tree_util.DictKey("scan_layers"), jax.tree_util.DictKey("wq"))
    spec = sh.param_spec(mesh, path, leaf, 2)
    assert spec == P(("site",), None, "fsdp", "model") or \
        spec == P("site", None, "fsdp", "model")


def test_indivisible_vocab_falls_back():
    mesh = _fake_mesh(2, 2, 16)
    leaf = jax.ShapeDtypeStruct((49155, 2048), jnp.float32)  # prime-ish vocab
    spec = sh.param_spec(mesh, (jax.tree_util.DictKey("embed"),), leaf, 0)
    assert spec[0] is None                     # vocab can't shard over 16
    assert spec[1] is not None                 # d_model picks up an axis


def test_hlo_analyzer_counts_scan_trips():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    txt = jax.jit(scanned).lower(x, ws).compile().as_text()
    costs = H.analyze(txt)
    assert costs.flops == pytest.approx(5 * 2 * 32 * 64 * 64)
    assert costs.dot_count == 5


def test_hlo_analyzer_nested_scans_multiply():
    def outer(x, ws):
        def ob(x, w):
            def ib(x, _):
                return jnp.tanh(x @ w), None
            return jax.lax.scan(ib, x, None, length=3)[0], None
        return jax.lax.scan(ob, x, ws)[0]

    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    txt = jax.jit(outer).lower(x, ws).compile().as_text()
    costs = H.analyze(txt)
    assert costs.flops == pytest.approx(4 * 3 * 2 * 16 * 32 * 32)


def test_hlo_analyzer_shape_bytes():
    assert H._shape_bytes("f32[2,3]") == 24
    assert H._shape_bytes("bf16[8]") == 16
    assert H._shape_bytes("(s32[], f32[4])") == 20
    assert H._shape_bytes("pred[10]") == 10


def test_shardhints_noop_when_disabled():
    x = jnp.ones((2, 4, 8, 16))
    y = shardhints.constrain_heads(x)
    assert y is x                              # no mesh context, no-op


def test_shardhints_skips_indivisible_heads():
    with shardhints.enable(model_axis=16):
        x = jnp.ones((2, 4, 9, 16))            # 9 heads % 16 != 0
        y = shardhints.constrain_heads(x)
        assert y is x


def test_mesh_config_validation():
    MeshConfig(sites_per_pod=16, fsdp=1).validate_for_pod(256)
    MeshConfig(sites_per_pod=16, fsdp=4, model_parallel=4).validate_for_pod(256)
    with pytest.raises(AssertionError):
        MeshConfig(sites_per_pod=16, fsdp=2).validate_for_pod(256)


def test_make_fl_mesh_shapes():
    """Mesh factorizations on abstract meshes (no XLA devices needed)."""
    cfg = MeshConfig(sites_per_pod=8, fsdp=2)
    assert cfg.total_sites == 8
    assert cfg.total_devices == 256
    cfg2 = MeshConfig(sites_per_pod=8, fsdp=2, multi_pod=True)
    assert cfg2.total_sites == 16
    assert cfg2.total_devices == 512


def test_train_microbatch_table_covers_all_archs():
    from repro.configs.registry import ARCH_IDS, get_arch
    from repro.launch.steps import TRAIN_MICROBATCH
    for aid in ARCH_IDS:
        if aid == "sanet_openkbp":
            continue
        name = get_arch(aid).CONFIG.name
        assert name in TRAIN_MICROBATCH, name


def test_make_site_mesh_defaults_to_all_devices():
    from repro.launch.mesh import make_site_mesh
    mesh = make_site_mesh()
    assert mesh.axis_names == ("site",)
    assert mesh.devices.ndim == 1
    assert mesh.devices.size == len(jax.devices())


def test_make_site_mesh_prefix_and_bounds():
    from repro.launch.mesh import make_site_mesh
    mesh = make_site_mesh(num_devices=1)          # tests pin one device
    assert mesh.devices.size == 1
    assert mesh.devices.flat[0] == jax.devices()[0]
    with pytest.raises(ValueError, match="num_devices"):
        make_site_mesh(num_devices=0)
    with pytest.raises(ValueError, match="num_devices"):
        make_site_mesh(num_devices=len(jax.devices()) + 1)
