"""Communication stack: codec framing, aggregation server, P2P exchange."""
import threading

import numpy as np
import pytest

from repro.comms.codec import decode_message, encode_message
from repro.comms.coordinator import AggregationServer, CoordinationServer
from repro.comms.peer import Peer


def test_codec_header_magic():
    data = encode_message("x", {}, None)
    with pytest.raises(ValueError):
        decode_message(b"XXXX" + data[4:])


def test_centralized_roundtrip_weighted():
    """Upload from 4 sites with case weights -> download == Eq. 1 average."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=4,
                            case_weights=[1.0, 2.0, 3.0, 4.0])
    peers = [Peer(i) for i in range(4)]
    try:
        threads = [threading.Thread(
            target=peers[i].upload, args=(agg.addr, {"w": np.full(3, float(i))}, 1))
            for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        g = peers[0].download(agg.addr, 1)
        want = sum(i * (i + 1) for i in range(4)) / 10.0
        np.testing.assert_allclose(g["w"], want, rtol=1e-6)
    finally:
        for p in peers:
            p.close()
        agg.stop()


def test_partial_round_with_dropout():
    """3 of 4 sites active: aggregation proceeds once 3 upload."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=4)
    peers = [Peer(i) for i in range(3)]
    try:
        for i, p in enumerate(peers):
            p.upload(agg.addr, {"w": np.full(2, float(i))}, 1, active_sites=3)
        g = peers[0].download(agg.addr, 1)
        np.testing.assert_allclose(g["w"], 1.0, rtol=1e-6)
    finally:
        for p in peers:
            p.close()
        agg.stop()


def test_decentralized_pairing_and_p2p():
    coord = CoordinationServer("127.0.0.1", 0, num_sites=4, seed=3)
    peers = [Peer(i) for i in range(4)]
    try:
        for p in peers:
            p.register(coord.addr)
        asg = peers[0].get_assignment(coord.addr, 1)
        assert len(asg["partner"]) == 4
        n_recv = sum(asg["is_receiver"])
        assert n_recv == 2
        for r in range(4):
            if asg["is_receiver"][r]:
                s = asg["partner"][r]
                peers[s].send_model(tuple(asg["addresses"][str(r)]),
                                    {"w": np.full(4, float(s))}, 1)
        for r in range(4):
            if asg["is_receiver"][r]:
                meta, tree = peers[r].recv_model(timeout=5)
                assert meta["site"] == asg["partner"][r]
                np.testing.assert_allclose(tree["w"], float(meta["site"]))
    finally:
        for p in peers:
            p.close()
        coord.stop()


def test_remote_error_propagates():
    agg = AggregationServer("127.0.0.1", 0, num_sites=2)
    p = Peer(0)
    try:
        with pytest.raises(RuntimeError, match="remote error"):
            p._channel(agg.addr).request("bogus_rpc", {}, None)
    finally:
        p.close()
        agg.stop()
