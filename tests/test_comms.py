"""Communication stack: codec framing, aggregation server, P2P exchange."""
import threading

import numpy as np
import pytest

from repro.comms.codec import decode_message, encode_message
from repro.comms.coordinator import AggregationServer, CoordinationServer
from repro.comms.peer import Peer


def test_codec_header_magic():
    data = encode_message("x", {}, None)
    with pytest.raises(ValueError):
        decode_message(b"XXXX" + data[4:])


def test_codec_decode_readonly_vs_writable():
    """Default decode returns zero-copy read-only views; ``writable=True``
    returns owned buffers an in-place consumer can mutate (regression for
    'assignment destination is read-only' in the streaming server)."""
    tree = {"w": np.arange(8, dtype=np.float32)}
    data = encode_message("model", {"site": 0}, tree)
    _, _, ro = decode_message(data)
    with pytest.raises(ValueError, match="read-only"):
        ro["w"] *= 2.0
    _, _, rw = decode_message(data, writable=True)
    rw["w"] *= 2.0                                   # in place, no error
    np.testing.assert_array_equal(rw["w"], tree["w"] * 2.0)
    # the writable copy does not alias the wire buffer
    _, _, again = decode_message(data)
    np.testing.assert_array_equal(again["w"], tree["w"])


def test_download_timeout_returns_error_not_none():
    """A download that outwaits the round must fail loudly at the server
    (error reply → RuntimeError at the client), not hand back tree=None."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=2, download_timeout=0.2)
    p = Peer(0)
    try:
        p.upload(agg.addr, {"w": np.ones(3, np.float32)}, 1)  # 1 of 2 sites
        with pytest.raises(RuntimeError, match="timeout"):
            p.download(agg.addr, 1)
    finally:
        p.close()
        agg.stop()


def test_centralized_roundtrip_weighted():
    """Upload from 4 sites with case weights -> download == Eq. 1 average."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=4,
                            case_weights=[1.0, 2.0, 3.0, 4.0])
    peers = [Peer(i) for i in range(4)]
    try:
        threads = [threading.Thread(
            target=peers[i].upload, args=(agg.addr, {"w": np.full(3, float(i))}, 1))
            for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        g = peers[0].download(agg.addr, 1)
        want = sum(i * (i + 1) for i in range(4)) / 10.0
        np.testing.assert_allclose(g["w"], want, rtol=1e-6)
    finally:
        for p in peers:
            p.close()
        agg.stop()


def test_partial_round_with_dropout():
    """3 of 4 sites active: aggregation proceeds once 3 upload."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=4)
    peers = [Peer(i) for i in range(3)]
    try:
        for i, p in enumerate(peers):
            p.upload(agg.addr, {"w": np.full(2, float(i))}, 1, active_sites=3)
        g = peers[0].download(agg.addr, 1)
        np.testing.assert_allclose(g["w"], 1.0, rtol=1e-6)
    finally:
        for p in peers:
            p.close()
        agg.stop()


def test_decentralized_pairing_and_p2p():
    coord = CoordinationServer("127.0.0.1", 0, num_sites=4, seed=3)
    peers = [Peer(i) for i in range(4)]
    try:
        for p in peers:
            p.register(coord.addr)
        asg = peers[0].get_assignment(coord.addr, 1)
        assert len(asg["partner"]) == 4
        n_recv = sum(asg["is_receiver"])
        assert n_recv == 2
        for r in range(4):
            if asg["is_receiver"][r]:
                s = asg["partner"][r]
                peers[s].send_model(tuple(asg["addresses"][str(r)]),
                                    {"w": np.full(4, float(s))}, 1)
        for r in range(4):
            if asg["is_receiver"][r]:
                meta, tree = peers[r].recv_model(timeout=5)
                assert meta["site"] == asg["partner"][r]
                np.testing.assert_allclose(tree["w"], float(meta["site"]))
    finally:
        for p in peers:
            p.close()
        coord.stop()


def test_remote_error_propagates():
    agg = AggregationServer("127.0.0.1", 0, num_sites=2)
    p = Peer(0)
    try:
        with pytest.raises(RuntimeError, match="remote error"):
            p._channel(agg.addr).request("bogus_rpc", {}, None)
    finally:
        p.close()
        agg.stop()
