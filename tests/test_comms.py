"""Communication stack: codec framing, aggregation server, P2P exchange."""
import threading

import numpy as np
import pytest

from repro.comms.codec import decode_message, encode_message
from repro.comms.coordinator import AggregationServer, CoordinationServer
from repro.comms.peer import Peer


def test_codec_header_magic():
    data = encode_message("x", {}, None)
    with pytest.raises(ValueError):
        decode_message(b"XXXX" + data[4:])


def test_codec_decode_readonly_vs_writable():
    """Default decode returns zero-copy read-only views; ``writable=True``
    returns owned buffers an in-place consumer can mutate (regression for
    'assignment destination is read-only' in the streaming server)."""
    tree = {"w": np.arange(8, dtype=np.float32)}
    data = encode_message("model", {"site": 0}, tree)
    _, _, ro = decode_message(data)
    with pytest.raises(ValueError, match="read-only"):
        ro["w"] *= 2.0
    _, _, rw = decode_message(data, writable=True)
    rw["w"] *= 2.0                                   # in place, no error
    np.testing.assert_array_equal(rw["w"], tree["w"] * 2.0)
    # the writable copy does not alias the wire buffer
    _, _, again = decode_message(data)
    np.testing.assert_array_equal(again["w"], tree["w"])


def test_download_timeout_returns_error_not_none():
    """A download that outwaits the round must fail loudly at the server
    (error reply → RuntimeError at the client), not hand back tree=None."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=2, download_timeout=0.2)
    p = Peer(0)
    try:
        p.upload(agg.addr, {"w": np.ones(3, np.float32)}, 1)  # 1 of 2 sites
        with pytest.raises(RuntimeError, match="timeout"):
            p.download(agg.addr, 1)
    finally:
        p.close()
        agg.stop()


def test_centralized_roundtrip_weighted():
    """Upload from 4 sites with case weights -> download == Eq. 1 average."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=4,
                            case_weights=[1.0, 2.0, 3.0, 4.0])
    peers = [Peer(i) for i in range(4)]
    try:
        threads = [threading.Thread(
            target=peers[i].upload, args=(agg.addr, {"w": np.full(3, float(i))}, 1))
            for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        g = peers[0].download(agg.addr, 1)
        want = sum(i * (i + 1) for i in range(4)) / 10.0
        np.testing.assert_allclose(g["w"], want, rtol=1e-6)
    finally:
        for p in peers:
            p.close()
        agg.stop()


def test_partial_round_with_dropout():
    """3 of 4 sites active: aggregation proceeds once 3 upload."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=4)
    peers = [Peer(i) for i in range(3)]
    try:
        for i, p in enumerate(peers):
            p.upload(agg.addr, {"w": np.full(2, float(i))}, 1, active_sites=3)
        g = peers[0].download(agg.addr, 1)
        np.testing.assert_allclose(g["w"], 1.0, rtol=1e-6)
    finally:
        for p in peers:
            p.close()
        agg.stop()


def test_decentralized_pairing_and_p2p():
    coord = CoordinationServer("127.0.0.1", 0, num_sites=4, seed=3)
    peers = [Peer(i) for i in range(4)]
    try:
        for p in peers:
            p.register(coord.addr)
        asg = peers[0].get_assignment(coord.addr, 1)
        assert len(asg["partner"]) == 4
        n_recv = sum(asg["is_receiver"])
        assert n_recv == 2
        for r in range(4):
            if asg["is_receiver"][r]:
                s = asg["partner"][r]
                peers[s].send_model(tuple(asg["addresses"][str(r)]),
                                    {"w": np.full(4, float(s))}, 1)
        for r in range(4):
            if asg["is_receiver"][r]:
                meta, tree = peers[r].recv_model(timeout=5)
                assert meta["site"] == asg["partner"][r]
                np.testing.assert_allclose(tree["w"], float(meta["site"]))
    finally:
        for p in peers:
            p.close()
        coord.stop()


def test_remote_error_propagates():
    agg = AggregationServer("127.0.0.1", 0, num_sites=2)
    p = Peer(0)
    try:
        with pytest.raises(RuntimeError, match="remote error"):
            p._channel(agg.addr).request("bogus_rpc", {}, None)
    finally:
        p.close()
        agg.stop()


# ---------------------------------------------------------------------------
# Sessioned wire: handshake (version + auth), TLS, streaming, retry
# ---------------------------------------------------------------------------

import shutil                                              # noqa: E402
import subprocess                                          # noqa: E402

import jax                                                 # noqa: E402

from repro.api import FederatedJob, TaskConfig             # noqa: E402
from repro.comms.codec import chunk_spans, encode_message as _enc  # noqa: E402
from repro.comms.membership import HeartbeatClient, LeaseRegistry  # noqa: E402
from repro.comms.transport import (AuthError, Channel, ChannelError,  # noqa: E402
                                   FlakyChannel, PeerClosed,
                                   ProtocolVersionError, Server, WireConfig)


def _echo_server(wire=None):
    def handler(kind, meta, tree):
        return _enc("echo", meta, tree)
    return Server("127.0.0.1", 0, handler, wire=wire).start()


def test_hello_version_mismatch_rejected_typed():
    """A peer speaking the wrong PROTOCOL_VERSION is refused at the
    handshake with a typed error, not silently served garbage."""
    srv = _echo_server(wire=WireConfig())
    try:
        class _OldChannel(Channel):
            proto_version = 99
        with pytest.raises(ProtocolVersionError, match="version"):
            _OldChannel(srv.addr)
    finally:
        srv.stop()


def test_hello_auth_token_verified():
    """With a job secret set, a missing or wrong HMAC token is a typed
    AuthError at connect time; the matched secret round-trips rpcs."""
    srv = _echo_server(wire=WireConfig(secret="s3cret"))
    try:
        with pytest.raises(AuthError):
            Channel(srv.addr, wire=WireConfig())            # no token
        with pytest.raises(AuthError):
            Channel(srv.addr, wire=WireConfig(secret="wrong"))
        ch = Channel(srv.addr, wire=WireConfig(secret="s3cret"),
                     identity="site:0")
        kind, meta, _ = ch.request("ping", {"x": 42})
        assert kind == "echo" and meta["x"] == 42
        ch.close()
    finally:
        srv.stop()


def test_tls_wire_roundtrip(tmp_path):
    """Self-signed TLS on both ends of the socket (cert pinned by the
    client) — gated on the openssl binary being present."""
    openssl = shutil.which("openssl")
    if openssl is None:
        pytest.skip("openssl not available to mint a test cert")
    cert, key = str(tmp_path / "c.pem"), str(tmp_path / "k.pem")
    subprocess.run(
        [openssl, "req", "-x509", "-newkey", "rsa:2048", "-keyout", key,
         "-out", cert, "-days", "1", "-nodes", "-subj", "/CN=127.0.0.1"],
        check=True, capture_output=True)
    wire = WireConfig(tls_cert=cert, tls_key=key, secret="s")
    srv = _echo_server(wire=wire)
    try:
        ch = Channel(srv.addr, wire=wire, identity="site:0")
        kind, meta, tree = ch.request("ping", {"x": 1},
                                      {"w": np.ones(4, np.float32)})
        assert kind == "echo" and meta["x"] == 1
        np.testing.assert_array_equal(tree["w"], 1.0)
        ch.close()
    finally:
        srv.stop()


def test_streamed_upload_bit_identical_and_counted_once():
    """An upload above max_message_size crosses as begin/chunk/commit
    frames and reassembles byte-identically: the aggregated global
    equals the single-frame path bit for bit, and WireStats counts ONE
    upload whose bytes include every chunk."""
    tree = {"w": np.arange(12288, dtype=np.float32)}
    encoded_len = len(_enc("upload", {"site": 0, "round": 1}, tree))
    mms = 4096
    assert len(chunk_spans(encoded_len, mms)) >= 4      # really streams
    globals_, stats = [], []
    for wire in (None, WireConfig(max_message_size=mms)):
        agg = AggregationServer("127.0.0.1", 0, num_sites=1, wire=wire)
        p = Peer(0, wire=wire)
        try:
            p.upload(agg.addr, tree, 1)
            globals_.append(p.download(agg.addr, 1))
            stats.append(agg.stats.snapshot())
        finally:
            p.close()
            agg.stop()
    np.testing.assert_array_equal(globals_[0]["w"], globals_[1]["w"])
    assert stats[1]["upload"]["count"] == 1             # chunks ≠ uploads
    assert stats[1]["upload"]["in_bytes"] >= encoded_len


def test_flaky_channel_reconnects_and_replays():
    """Dropped/duplicated frames are retried transparently: every
    request still returns its own reply, in order."""
    srv = _echo_server()
    try:
        ch = FlakyChannel(srv.addr, drop=0.25, dup=0.25, seed=0,
                          wire=WireConfig(connect_retries=10,
                                          backoff_base=0.005))
        for i in range(25):
            kind, meta, _ = ch.request("ping", {"i": i})
            assert kind == "echo" and meta["i"] == i
        ch.close()
    finally:
        srv.stop()


def test_channel_connect_budget_exhausts_typed():
    wire = WireConfig(connect_retries=1, backoff_base=0.001)
    with pytest.raises(ChannelError):
        Channel(("127.0.0.1", 1), timeout=0.3, wire=wire)   # nothing listens


def test_many_sites_connect_burst_one_round():
    """Cross-device regression: 128 sites dial the aggregation server in
    one synchronized burst and all upload within a single round.  The
    listen backlog (raised from 64) must absorb the SYN storm without
    refusing anyone, and the fold must count every site exactly once."""
    n = 128
    agg = AggregationServer("127.0.0.1", 0, num_sites=n, download_timeout=60)
    chans: list = [None] * n
    errors: list = []
    gate = threading.Barrier(n)

    def site(i):
        try:
            gate.wait(timeout=30)                   # connect all at once
            ch = Channel(agg.addr, timeout=60, identity=f"site:{i}")
            chans[i] = ch
            ch.request("upload", {"site": i, "round": 1},
                       {"w": np.full(4, float(i), np.float32)})
        except Exception as e:  # noqa: BLE001 — surfaced by the assert
            errors.append((i, e))

    threads = [threading.Thread(target=site, args=(i,)) for i in range(n)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors, f"refused/failed connections: {errors[:5]}"
        _, _, g = chans[0].request("download", {"round": 1}, None)
        np.testing.assert_allclose(g["w"], (n - 1) / 2.0, rtol=1e-6)
    finally:
        for ch in chans:
            if ch is not None:
                ch.close()
        agg.stop()


@pytest.mark.parametrize("transport", ["thread", "tcp"])
def test_flaky_wire_job_matches_clean(transport):
    """End to end: a job over an injected-fault wire (drops + dups on
    every channel) converges to the SAME model as the clean wire — the
    reconnect/replay + server dedup machinery is invisible to FL math."""
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=2, batch=2,
                        seq=16, seed=0),
        strategy="fedavg", rounds=2, seed=0, transport=transport,
        io_timeout=120)
    clean = FederatedJob(**base).run()
    flaky = FederatedJob(
        **base, wire=WireConfig(flaky="drop=0.15,dup=0.1,seed=3",
                                connect_retries=8, backoff_base=0.01)).run()
    for a, b in zip(jax.tree.leaves(clean.global_params),
                    jax.tree.leaves(flaky.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Elastic membership: leases, heartbeats, late joiners
# ---------------------------------------------------------------------------


def test_lease_registry_expected_semantics():
    reg = LeaseRegistry(ttl=60.0)
    assert reg.expected(4) == 4            # leases not in use yet
    reg.join(0)
    reg.join(1)
    assert reg.expected(4) == 2            # never wait for more than live
    assert reg.expected(1) == 1
    reg.leave(1)
    reg.leave(0)
    assert reg.expected(4) == 1            # never below one survivor


def test_lease_expiry_unblocks_flat_barrier():
    """A site that joins then goes silent expires after the ttl and the
    round finalizes from the survivors instead of deadlocking."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=2, lease_ttl=0.4,
                            download_timeout=10)
    p0, p1 = Peer(0), Peer(1)
    hb = None
    try:
        hb = HeartbeatClient(0, lambda k, m: p0.request(agg.addr, k, m),
                             0.4).start()
        p1.request(agg.addr, "join", {"site": 1})      # joins, never beats
        p0.upload(agg.addr, {"w": np.ones(3, np.float32)}, 1, active_sites=2)
        g = p0.download(agg.addr, 1)                   # waits out the lease
        np.testing.assert_allclose(g["w"], 1.0)
        assert any(s == 1 for _, s in agg.registry.expired_log)
    finally:
        if hb is not None:
            hb.stop()
        p0.close()
        p1.close()
        agg.stop()


def test_graceful_leave_shrinks_barrier_immediately():
    """An explicit leave drops the lease now — the barrier does not have
    to wait out the ttl."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=2, lease_ttl=30.0,
                            download_timeout=10)
    p0, p1 = Peer(0), Peer(1)
    try:
        p0.request(agg.addr, "join", {"site": 0})
        p1.request(agg.addr, "join", {"site": 1})
        p1.request(agg.addr, "leave", {"site": 1})
        p0.upload(agg.addr, {"w": np.full(3, 2.0, np.float32)}, 1,
                  active_sites=2)
        g = p0.download(agg.addr, 1)
        np.testing.assert_allclose(g["w"], 2.0)
    finally:
        p0.close()
        p1.close()
        agg.stop()


def test_late_joiner_bootstrap_carries_current_global():
    """The join reply doubles as the late-joiner bootstrap: current
    server round + a dense copy of the current global."""
    g0 = {"w": np.full(4, 7.0, np.float32)}
    agg = AggregationServer("127.0.0.1", 0, num_sites=2, lease_ttl=5.0,
                            initial_round=3, initial_global=g0)
    p = Peer(5)
    hb = None
    try:
        hb = HeartbeatClient(5, lambda k, m: p.request(agg.addr, k, m),
                             5.0).start()
        assert hb.join_meta["round"] == 3
        np.testing.assert_array_equal(np.asarray(hb.bootstrap["w"]), g0["w"])
    finally:
        if hb is not None:
            hb.stop()
        p.close()
        agg.stop()


# ---------------------------------------------------------------------------
# Peer shutdown semantics
# ---------------------------------------------------------------------------


def test_peer_close_wakes_blocked_receiver_typed():
    p = Peer(9)
    caught = []

    def recv():
        try:
            p.recv_model(timeout=10)
        except Exception as e:  # noqa: BLE001
            caught.append(e)

    t = threading.Thread(target=recv)
    t.start()
    p.close()
    t.join(timeout=5)
    assert not t.is_alive()
    assert len(caught) == 1 and isinstance(caught[0], PeerClosed)
    with pytest.raises(PeerClosed):                    # and ever after
        p.recv_model(timeout=0.1)


def test_recv_model_timeout_is_timeouterror():
    p = Peer(8)
    try:
        with pytest.raises(TimeoutError):
            p.recv_model(timeout=0.2)
    finally:
        p.close()


def test_lease_expiry_unblocks_pod_tier_barrier():
    """Same elastic rule one tier down: a silent pod member expires and
    the pod partial finalizes from the survivors, so the leader's
    pod_partial pull does not deadlock."""
    from repro.comms.pods import PodAggregationServer
    pod = PodAggregationServer("127.0.0.1", 0, num_sites=2, pod_id=0,
                               lease_ttl=0.4, download_timeout=10)
    p0, p1 = Peer(0), Peer(1)
    hb = None
    try:
        hb = HeartbeatClient(0, lambda k, m: p0.request(pod.addr, k, m),
                             0.4).start()
        p1.request(pod.addr, "join", {"site": 1})      # joins, never beats
        p0.upload(pod.addr, {"w": np.full(3, 5.0, np.float32)}, 1,
                  active_sites=2)
        kind, meta, tree = p0.request(pod.addr, "pod_partial", {"round": 1})
        assert kind == "partial" and meta["round"] == 1
        np.testing.assert_allclose(tree["w"], 5.0)
        assert any(s == 1 for _, s in pod.registry.expired_log)
    finally:
        if hb is not None:
            hb.stop()
        p0.close()
        p1.close()
        pod.stop()
