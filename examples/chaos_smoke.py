"""Chaos smoke: everything hostile at once, still converges.

One tcp job takes all of PR 9's fault axes simultaneously —

* one sign-flipping Byzantine site (``adversary="sign_flip:1"``),
* a robust aggregation rule at the server (``aggregator="trimmed:1"``),
* a flaky wire dropping 10% of frames and corrupting 2%
  (``WireConfig.flaky``; clients retry typed drop/corrupt errors),
* elastic membership (``lease_ttl``) with one site SIGKILLed mid-run —
  its lease expires and the survivors' barrier shrinks past it —

and must end within tolerance of a clean stacked fedavg reference.
The site processes are multiprocessing children of this driver, so a
watcher thread picks one honest site and SIGKILLs it once the job is
past its first rounds.

    PYTHONPATH=src python examples/chaos_smoke.py
"""
import multiprocessing
import os
import signal
import sys
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.api import FederatedJob, TaskConfig, WireConfig  # noqa: E402
from repro.core.adversary import parse_adversary  # noqa: E402

SITES = int(os.environ.get("FEDKBP_SITES", "4"))
ROUNDS = int(os.environ.get("FEDKBP_ROUNDS", "6"))
SEED = 0


def _task():
    return TaskConfig(kind="tokens", arch="smollm-135m", sites=SITES,
                      batch=2, seq=16, heterogeneity=0.3, seed=SEED)


def _kill_one_site_later(delay_s: float):
    """SIGKILL one spawned site process after ``delay_s`` — an honest
    one, so the Byzantine site keeps attacking the survivors."""
    plan = parse_adversary("sign_flip:1", seed=SEED)
    mask = plan.malicious_mask(SITES)
    honest = [i for i in range(SITES) if not mask[i]]

    def _killer():
        deadline = time.time() + 120
        while time.time() < deadline:
            kids = multiprocessing.active_children()
            if len(kids) >= SITES:
                break
            time.sleep(0.2)
        else:
            return
        time.sleep(delay_s)
        kids = sorted(multiprocessing.active_children(), key=lambda p: p.pid)
        victim = kids[min(honest[-1], len(kids) - 1)]
        print(f"chaos: SIGKILL site process pid={victim.pid}")
        os.kill(victim.pid, signal.SIGKILL)

    t = threading.Thread(target=_killer, daemon=True)
    t.start()
    return t


def main():
    print("clean stacked fedavg reference…")
    ref = FederatedJob(task=_task(), strategy="fedavg", rounds=ROUNDS,
                       local_steps=2, lr=1e-3, seed=SEED,
                       verbose=False).run()
    clean = ref.history[-1]["loss"]
    print(f"clean loss {clean:.4f}")

    print("chaos run: tcp + trimmed:1 + sign_flip:1 + flaky wire "
          "+ SIGKILLed site…")
    job = FederatedJob(
        task=_task(), strategy="fedavg", rounds=ROUNDS, local_steps=2,
        lr=1e-3, seed=SEED, transport="tcp", verbose=False,
        aggregator="trimmed:1", adversary="sign_flip:1",
        lease_ttl=2.0,
        wire=WireConfig(flaky="drop=0.1,corrupt=0.02,seed=3"))
    killer = _kill_one_site_later(delay_s=8.0)
    res = job.run()
    killer.join(timeout=1)
    chaos = res.history[-1]["loss"]
    drift = abs(chaos - clean) / clean
    print(f"chaos loss {chaos:.4f} (clean {clean:.4f}, drift {drift:.1%}, "
          f"rejected_uploads={res.rejected_uploads})")
    assert drift < 0.10, (
        f"chaos run drifted {drift:.1%} from the clean reference "
        f"({chaos:.4f} vs {clean:.4f})")
    print("OK — Byzantine + flaky wire + crash, within 10% of clean")


if __name__ == "__main__":
    main()
