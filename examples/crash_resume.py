"""Kill-and-resume: SIGKILL a multi-process tcp job mid-run, re-enter
it with ``--resume``, and verify the final global matches an
uninterrupted reference run.

The job checkpoints on every round (driver store + per-site sub-stores
under ``out/ckpt``); the kill lands after at least one checkpoint has
hit disk, so the rerun re-enters from the newest round present in every
store and finishes the remaining rounds.  Checkpoint-aligned resume is
loss-trajectory-identical, so the two final globals agree to float
noise (upload arrival order varies the fp32 fold order slightly).

    PYTHONPATH=src python examples/crash_resume.py
"""
import os
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

SITES = int(os.environ.get("FEDKBP_SITES", "2"))
ROUNDS = int(os.environ.get("FEDKBP_ROUNDS", "6"))


def _train_cmd(out: Path, resume: bool = False):
    cmd = [sys.executable, "-m", "repro.launch.train", "--reduced",
           "--sites", str(SITES), "--rounds", str(ROUNDS),
           "--batch", "2", "--seq", "16", "--transport", "tcp",
           "--checkpoint", "--ckpt-every", "1", "--quiet",
           "--out", str(out)]
    if resume:
        cmd.append("--resume")
    return cmd


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _final_global(ckpt: Path):
    from repro.checkpoint import CheckpointStore
    store = CheckpointStore(ckpt)
    rounds = store.saved_rounds("global")
    assert rounds, f"no global checkpoints under {ckpt}"
    rec = max(rounds)
    data = np.load(ckpt / f"global_round{rec:06d}.npz")
    return rec, {k: data[k] for k in data.files if k != "__treedef__"}


def main():
    with tempfile.TemporaryDirectory() as tmp:
        ref_out, out = Path(tmp) / "ref", Path(tmp) / "crashed"

        print("reference run (uninterrupted)…")
        subprocess.run(_train_cmd(ref_out), env=_env(), check=True)

        print("victim run (to be SIGKILLed mid-job)…")
        # own process group so the kill takes the daemonic site processes
        # down with the driver — exactly a machine crash, no cleanup
        proc = subprocess.Popen(_train_cmd(out), env=_env(),
                                start_new_session=True)
        ckpt = out / "ckpt"
        deadline = time.time() + 300
        while time.time() < deadline:
            if proc.poll() is not None:
                raise SystemExit("victim finished before the kill — "
                                 "raise FEDKBP_ROUNDS")
            if list(ckpt.glob("global_round*.npz")):
                break
            time.sleep(0.2)
        time.sleep(0.3)                     # land the kill mid-round
        os.killpg(proc.pid, signal.SIGKILL)
        proc.wait()
        print(f"killed mid-job (exit {proc.returncode}); resuming…")

        subprocess.run(_train_cmd(out, resume=True), env=_env(), check=True)

        ref_round, ref_g = _final_global(ref_out / "ckpt")
        res_round, res_g = _final_global(ckpt)
        assert ref_round == res_round == ROUNDS - 1, (ref_round, res_round)
        assert set(ref_g) == set(res_g)
        for k in ref_g:
            np.testing.assert_allclose(res_g[k], ref_g[k],
                                       rtol=1e-4, atol=1e-5, err_msg=k)
        print(f"OK — resumed job reached round {res_round} with the same "
              f"global as the uninterrupted reference "
              f"({len(ref_g)} leaves checked)")


if __name__ == "__main__":
    main()
