"""Quickstart: federated training of an assigned architecture in ~a minute.

Trains a reduced Qwen3 on synthetic non-IID token streams across 4 sites
with FedAvg through the unified ``FederatedJob`` API, then serves the
aggregated global model.  The same job runs distributed by flipping
``transport="tcp"`` (see examples/distributed_sites.py).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.api import FederatedJob, TaskConfig
from repro.models import transformer as T

SITES = int(os.environ.get("FEDKBP_SITES", "4"))
ROUNDS = int(os.environ.get("FEDKBP_ROUNDS", "12"))

job = FederatedJob(
    task=TaskConfig(kind="tokens", arch="qwen3-8b", sites=SITES,
                    heterogeneity=0.5, batch=4, seq=32),
    strategy="fedavg", rounds=ROUNDS, lr=2e-3, verbose=True, log_every=1)

print(f"federated training: {job.task.arch} (reduced), {SITES} sites, FedAvg")
result = job.run()

# serve the aggregated global model
cfg = job.task.model_config()
g = result.global_params
prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
_, caches = T.prefill(g, prompt, cfg, cache_capacity=24, moe_impl="dense")
tok = prompt[:, -1:]
generated = []
for _ in range(8):
    logits, caches = T.decode_step(g, tok, caches, cfg, moe_impl="dense")
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated.append(int(tok[0, 0]))
print("generated token ids:", generated)
print("OK")
