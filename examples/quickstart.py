"""Quickstart: federated training of an assigned architecture in ~a minute.

Trains a reduced Qwen3 on synthetic non-IID token streams across 4 sites
with FedAvg, then serves the aggregated global model.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederationConfig, MeshConfig
from repro.configs.registry import get_arch
from repro.core import federation as F
from repro.data.synthetic import TokenTaskGenerator
from repro.models import transformer as T
from repro.optim import adamw

SITES, ROUNDS = 4, 12

cfg = get_arch("qwen3-8b").reduced()
gen = TokenTaskGenerator(vocab_size=cfg.vocab_size, num_sites=SITES,
                         heterogeneity=0.5, seed=0)

fed = FederationConfig(num_sites=SITES, strategy="fedavg")
ctx = F.FLContext(
    fed=fed, mesh=MeshConfig(sites_per_pod=SITES, fsdp=16 // SITES),
    case_weights=jnp.asarray(fed.case_weights()),
    loss_fn=lambda p, b: T.next_token_loss(p, b, cfg),
    logits_fn=None, optimizer=adamw(2e-3), grad_clip=1.0, dcml_lr=1e-3)

state = F.init_fl_state(ctx, lambda k: T.init(k, cfg), jax.random.PRNGKey(0))
fl_round = jax.jit(F.build_fl_round(ctx))

print(f"federated training: {cfg.name}, {SITES} sites, FedAvg")
for r in range(ROUNDS):
    batches = jax.tree.map(jnp.asarray, gen.stacked_batches(r, 1, 4, 32))
    state, metrics = fl_round(state, batches, F.make_round_inputs(ctx))
    print(f"  round {r:2d}  mean site loss {float(jnp.mean(metrics['loss'])):.4f}")

# serve the aggregated global model
g = F.global_model(state, ctx)
prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
_, caches = T.prefill(g, prompt, cfg, cache_capacity=24, moe_impl="dense")
tok = prompt[:, -1:]
generated = []
for _ in range(8):
    logits, caches = T.decode_step(g, tok, caches, cfg, moe_impl="dense")
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated.append(int(tok[0, 0]))
print("generated token ids:", generated)
print("OK")
