"""Decentralized FL with GCML under site churn (paper Fig 4 + Fig 15).

5 sites, gossip pairing each round, regional DCML mutual learning, and
Algorithm-2 random drop-in/out at up to 40% of sites.

    PYTHONPATH=src python examples/gossip_decentralized.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_sanet_ctx
from repro.core import federation as F
from repro.core.dropout import SiteAvailability
from repro.data.synthetic import SegTaskGenerator
from repro.models import sanet as sanet_mod

SITES, ROUNDS, MAX_DROP = 5, 10, 2

ctx, scfg = make_sanet_ctx("gcml", SITES, task="seg", scenario="shutdown")
gen = SegTaskGenerator(volume=(16, 16, 16), in_channels=2, num_classes=3,
                       num_sites=SITES, heterogeneity=0.5, seed=4)
state = F.init_fl_state(ctx, lambda k: sanet_mod.sanet_init(k, scfg),
                        jax.random.PRNGKey(0))
fl_round = jax.jit(F.build_fl_round(ctx))
avail = SiteAvailability(SITES, MAX_DROP, seed=3)
rng = np.random.default_rng(0)

print(f"GCML gossip, {SITES} sites, up to {MAX_DROP * 100 // SITES}0% dropout")
for r in range(ROUNDS):
    b = jax.tree.map(jnp.asarray, gen.stacked_batches(r, 1, 2))
    ri = F.make_round_inputs(ctx, avail, rng, r)
    ri["dcml_batch"] = jax.tree.map(lambda x: x[:, 0], b)
    ri["val_batch"] = jax.tree.map(lambda x: x[:, -1], b)
    state, m = fl_round(state, b, ri)
    pairs = [(int(ri["partner"][i]), i) for i in range(SITES)
             if ri["is_receiver"][i]]
    print(f"  round {r:2d} loss {float(jnp.mean(m['loss'])):.4f} "
          f"active {int(np.sum(ri['active']))}/{SITES} "
          f"pairs(sender->receiver) {pairs}")
print("OK — model exchange continued despite churn (paper Fig 15)")
