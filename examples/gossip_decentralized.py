"""Decentralized FL with GCML under site churn (paper Fig 4 + Fig 15).

5 sites, gossip pairing each round, regional DCML mutual learning, and
Algorithm-2 random drop-in/out at up to 40% of sites — one declarative
``FederatedJob``; the pairing/dropout loop lives in the transport.

    PYTHONPATH=src python examples/gossip_decentralized.py
"""
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import FederatedJob, TaskConfig

SITES = int(os.environ.get("FEDKBP_SITES", "5"))
ROUNDS = int(os.environ.get("FEDKBP_ROUNDS", "10"))
MAX_DROP = 2

job = FederatedJob(
    task=TaskConfig(kind="seg", sites=SITES, heterogeneity=0.5, seed=4,
                    batch=2),
    strategy="gcml", rounds=ROUNDS, lr=3e-3,
    max_dropout=MAX_DROP, dropout_scenario="shutdown", seed=3)

print(f"GCML gossip, {SITES} sites, up to {MAX_DROP * 100 // SITES}% dropout")
res = job.run()
for h in res.history:
    pairs = [(int(h["partner"][i]), i) for i in range(SITES)
             if h["is_receiver"][i]]
    print(f"  round {h['round']:2d} loss {h['loss']:.4f} "
          f"active {h['active']}/{SITES} pairs(sender->receiver) {pairs}")
print("OK — model exchange continued despite churn (paper Fig 15)")
