"""End-to-end driver for the paper's flagship task (Figs 7/8): federated
3D dose prediction with SA-Net on OpenKBP-shaped synthetic volumes.

Runs the paper's three-way comparison — Pooled vs FedAvg vs Individual —
under the non-IID site split (Fig 6 case counts) and reports dose/DVH
scores on a common test set.

    PYTHONPATH=src python examples/federated_dose_prediction.py [--rounds N]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_sanet_ctx, run_fl
from repro.core import federation as F
from repro.data.partition import OPENKBP_NONIID_TRAIN
from repro.data.synthetic import DoseTaskGenerator
from repro.metrics import dose_score
from repro.models import sanet as sanet_mod

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=12)
args = ap.parse_args()

VOL = (16, 16, 16)
test = jax.tree.map(jnp.asarray,
                    DoseTaskGenerator(volume=VOL, num_oars=2, num_sites=1,
                                      seed=999).sample(0, 0, 8))

for strategy in ["pooled", "fedavg", "individual"]:
    sites = 1 if strategy == "pooled" else 8
    cw = None if strategy == "pooled" else tuple(OPENKBP_NONIID_TRAIN)
    ctx, scfg = make_sanet_ctx(strategy, sites, case_weights=cw)
    gen = DoseTaskGenerator(volume=VOL, num_oars=2, num_sites=sites,
                            heterogeneity=0.0 if sites == 1 else 0.6, seed=1)
    hist, state, _ = run_fl(ctx, scfg, gen, args.rounds,
                            batch=8 if strategy == "pooled" else 2)
    g = F.global_model(state, ctx)
    pred, _ = sanet_mod.sanet_apply(g, test["volume"], scfg)
    ds = np.mean([dose_score(np.asarray(pred[i, ..., 0]),
                             np.asarray(test["dose"][i, ..., 0]),
                             np.asarray(test["mask"][i, ..., 0]))
                  for i in range(8)])
    print(f"{strategy:12s} final_train_loss={hist[-1]:.4f} "
          f"test_dose_score={ds:.4f}")
print("expected ordering: pooled <= fedavg < individual (paper Fig 8)")
