"""End-to-end driver for the paper's flagship task (Figs 7/8): federated
3D dose prediction with SA-Net on OpenKBP-shaped synthetic volumes.

Runs the paper's three-way comparison — Pooled vs FedAvg vs Individual —
under the non-IID site split (Fig 6 case counts) as three declarative
``FederatedJob``s and reports dose scores on a common test set.

    PYTHONPATH=src python examples/federated_dose_prediction.py [--rounds N]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import FederatedJob, TaskConfig
from repro.data.partition import OPENKBP_NONIID_TRAIN
from repro.data.synthetic import DoseTaskGenerator
from repro.metrics import dose_score
from repro.models import sanet as sanet_mod

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=12)
args = ap.parse_args()

VOL = (16, 16, 16)
test = jax.tree.map(jnp.asarray,
                    DoseTaskGenerator(volume=VOL, num_oars=2, num_sites=1,
                                      seed=999).sample(0, 0, 8))

for strategy in ["pooled", "fedavg", "individual"]:
    pooled = strategy == "pooled"
    job = FederatedJob(
        task=TaskConfig(kind="dose", volume=VOL,
                        sites=1 if pooled else 8,
                        heterogeneity=0.0 if pooled else 0.6, seed=1,
                        batch=8 if pooled else 2),
        strategy=strategy, rounds=args.rounds, lr=3e-3,
        case_counts=None if pooled else tuple(OPENKBP_NONIID_TRAIN))
    res = job.run()
    scfg = job.task.model_config()
    pred, _ = sanet_mod.sanet_apply(res.global_params, test["volume"], scfg)
    ds = np.mean([dose_score(np.asarray(pred[i, ..., 0]),
                             np.asarray(test["dose"][i, ..., 0]),
                             np.asarray(test["mask"][i, ..., 0]))
                  for i in range(8)])
    print(f"{strategy:12s} final_train_loss={res.final_loss:.4f} "
          f"test_dose_score={ds:.4f}")
print("expected ordering: pooled <= fedavg < individual (paper Fig 8)")
