"""Real multi-process federation over the TCP comms stack (paper §II.D).

Each site runs in its own OS process with its own model, identified by
IP:port; round trips go through the AggregationServer exactly as the
paper's gRPC deployment does (upload → weighted aggregate → download).

    PYTHONPATH=src python examples/distributed_sites.py
"""
import multiprocessing as mp
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

SITES, ROUNDS = 4, 8


def site_process(site_id: int, server_addr, result_q):
    import jax
    import jax.numpy as jnp
    from repro.comms.peer import Peer
    from repro.configs.registry import get_arch
    from repro.models import transformer as T
    from repro.optim import adamw, apply_updates
    from repro.data.synthetic import TokenTaskGenerator

    cfg = get_arch("smollm-135m").reduced()
    gen = TokenTaskGenerator(vocab_size=cfg.vocab_size, num_sites=SITES,
                             heterogeneity=0.4, seed=0)
    params = T.init(jax.random.PRNGKey(0), cfg)       # shared init (paper)
    opt = adamw(5e-3)
    opt_state = opt.init(params)
    peer = Peer(site_id)

    @jax.jit
    def step(p, s, batch):
        (loss, _), g = jax.value_and_grad(
            lambda q: T.next_token_loss(q, batch, cfg), has_aux=True)(p)
        upd, s = opt.update(g, s, p)
        return apply_updates(p, upd), s, loss

    losses = []
    for r in range(1, ROUNDS + 1):
        toks = jnp.asarray(gen.sample(site_id, r, 4, 32))
        params, opt_state, loss = step(params, opt_state, {"tokens": toks})
        losses.append(float(loss))
        host = jax.tree.map(np.asarray, params)
        peer.upload(server_addr, host, r)             # gRPC-equivalent upload
        new_global = peer.download(server_addr, r)    # broadcast back
        params = jax.tree.map(jnp.asarray, new_global)
    peer.close()
    result_q.put((site_id, losses))


def main():
    from repro.comms.coordinator import AggregationServer
    server = AggregationServer("127.0.0.1", 0, num_sites=SITES)
    q = mp.Queue()
    procs = [mp.Process(target=site_process, args=(i, server.addr, q))
             for i in range(SITES)]
    for p in procs:
        p.start()
    results = sorted(q.get(timeout=300) for _ in range(SITES))
    for p in procs:
        p.join(timeout=30)
    server.stop()
    for site, losses in results:
        print(f"site {site}: losses {['%.3f' % l for l in losses]}")
    first = np.mean([np.mean(l[:2]) for _, l in results])
    last = np.mean([np.mean(l[-2:]) for _, l in results])
    print(f"mean loss {first:.4f} -> {last:.4f} across {SITES} real processes")
    assert last < first + 0.02, (first, last)
    print("OK — multi-process FedAvg over TCP (the paper's deployment shape)")


if __name__ == "__main__":
    mp.set_start_method("spawn")
    main()
