"""Real multi-process federation over the TCP comms stack (paper §II.D).

The SAME ``FederatedJob`` that runs the single-process simulator runs
here with ``transport="tcp"``: each site becomes its own OS process with
its own model, identified by IP:port; round trips go through the
``AggregationServer`` exactly as the paper's gRPC deployment does
(upload → weighted aggregate → download).

    PYTHONPATH=src python examples/distributed_sites.py
"""
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

SITES = int(os.environ.get("FEDKBP_SITES", "4"))
ROUNDS = int(os.environ.get("FEDKBP_ROUNDS", "8"))


def main():
    from repro.api import FederatedJob, TaskConfig

    job = FederatedJob(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=SITES,
                        heterogeneity=0.4, batch=4, seq=32),
        strategy="fedavg", rounds=ROUNDS, lr=5e-3, transport="tcp")
    res = job.run()

    losses = np.array([h["per_site_loss"] for h in res.history])   # [R, S]
    for site in range(SITES):
        print(f"site {site}: losses {['%.3f' % l for l in losses[:, site]]}")
    first = float(np.mean(losses[:2]))
    last = float(np.mean(losses[-2:]))
    print(f"mean loss {first:.4f} -> {last:.4f} across {SITES} real processes")
    assert last < first + 0.02, (first, last)
    print("OK — multi-process FedAvg over TCP (the paper's deployment shape)")


if __name__ == "__main__":
    main()
