"""Secure aggregation surviving a killed site (the CI privacy smoke).

Two phases:

  1. **DP + masks over tcp, end to end** — the ``repro.launch.train``
     CLI with ``--secure-agg --dp-clip 1.0 --dp-noise-multiplier 0.5``
     on the tcp transport: every site clips + noises its update
     locally, masks it pairwise in fixed point, and the job reports a
     finite (ε, δ) from the Rényi accountant.
  2. **Kill-and-lease-expire** — three real OS processes join one
     ``AggregationServer`` (lease_ttl set, SecureAggState armed); one
     is SIGKILLed *after joining the round's schedule* but before
     uploading, so its pairwise masks never cancel.  The reaper expires
     its lease, the server regenerates exactly the dead site's pair
     streams (seed escrow), and the published global is the survivors'
     exact weighted mean — a crashed participant costs its contribution,
     never the round.

    PYTHONPATH=src python examples/secure_dropout.py
"""
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

SITES = 3
LEASE_TTL = 3.0          # > the survivors' join→upload window below
JOIN_WINDOW = 1.5        # survivors hold uploads until everyone joined
SECRET = "example-mask-secret"


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _model(site: int) -> np.ndarray:
    return np.random.default_rng(site).normal(size=(256,)).astype(np.float32)


def _weight(site: int) -> float:
    return float(site + 1)


def worker(site: int, host: str, port: int, die: bool):
    """One site process: join the schedule, then either upload a masked
    model or (the victim) hang until SIGKILLed."""
    from repro.comms.peer import Peer
    from repro.privacy import SecureAggClient
    peer = Peer(site)
    peer.request((host, port), "join", {"site": site})
    if die:
        time.sleep(600)                      # killed long before this ends
    time.sleep(JOIN_WINDOW)                  # everyone joins the schedule
    enc, meta = SecureAggClient(SECRET, "site", site).encode(
        {"w": _model(site)}, _weight(site), list(range(SITES)), 0)
    ack = peer.upload((host, port), enc, 1, active_sites=SITES,
                      meta_extra=meta)
    assert not ack["stale"], f"site {site} upload rejected"
    peer.close()


def phase_dp_over_tcp():
    print("phase 1: DP-SGD + secure aggregation over tcp (train CLI)…")
    cmd = [sys.executable, "-m", "repro.launch.train", "--reduced",
           "--sites", "2", "--rounds", "2", "--batch", "2", "--seq", "16",
           "--transport", "tcp", "--secure-agg",
           "--dp-clip", "1.0", "--dp-noise-multiplier", "0.5",
           "--lease-ttl", "30", "--quiet", "--out", "/tmp/secure_dropout"]
    subprocess.run(cmd, env=_env(), check=True)
    rec = json.loads(
        Path("/tmp/secure_dropout/train_fedavg.json").read_text())
    losses = [h["loss"] for h in rec["history"]]
    assert np.isfinite(losses).all(), losses
    eps = rec["privacy"]["epsilon"]
    assert np.isfinite(eps) and eps > 0, rec["privacy"]
    print(f"  finished, losses {['%.3f' % l for l in losses]}, "
          f"epsilon={eps:.2f} at delta={rec['privacy']['delta']}")


def phase_kill_and_recover():
    print("phase 2: masked round with a SIGKILLed, lease-expired site…")
    from repro.comms.coordinator import AggregationServer
    from repro.comms.peer import Peer
    from repro.privacy import SecureAggState

    sa = SecureAggState(SECRET, "site", np.ones((1, SITES), bool))
    srv = AggregationServer("127.0.0.1", 0, num_sites=SITES,
                            case_weights=[_weight(s) for s in range(SITES)],
                            download_timeout=60.0, lease_ttl=LEASE_TTL,
                            secure_agg=sa)
    host, port = srv.addr
    victim_site = 1
    procs = {}
    try:
        for s in range(SITES):
            procs[s] = subprocess.Popen(
                [sys.executable, __file__, "--worker", str(s), host,
                 str(port), "die" if s == victim_site else "up"],
                env=_env(), start_new_session=True)
        # survivors upload inside the victim's lease window, so the
        # round barrier is genuinely waiting on the victim when it dies
        for s, p in procs.items():
            if s != victim_site:
                assert p.wait(timeout=120) == 0, f"site {s} failed"
        os.kill(procs[victim_site].pid, signal.SIGKILL)
        procs[victim_site].wait()
        print(f"  site {victim_site} SIGKILLed after joining the schedule; "
              f"waiting out its {LEASE_TTL}s lease…")

        peer = Peer(99)
        g = peer.download((host, port), 1)
        peer.close()
        alive = [s for s in range(SITES) if s != victim_site]
        expect = (sum(_weight(s) * _model(s) for s in alive)
                  / sum(_weight(s) for s in alive))
        np.testing.assert_allclose(g["w"], expect, rtol=1e-6, atol=1e-6)
        assert sa.recovered == [(0, victim_site)], sa.recovered
        print(f"  round repaired by seed recovery: global == exact weighted "
              f"mean of sites {alive} ({g['w'].size} params, "
              f"recovered pair streams for site {victim_site})")
    finally:
        for p in procs.values():
            if p.poll() is None:
                p.kill()
        srv.stop()


def main():
    phase_dp_over_tcp()
    phase_kill_and_recover()
    print("OK — DP + masked uploads over tcp, and a killed site repaired "
          "by lease-expiry seed recovery")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--worker":
        worker(int(sys.argv[2]), sys.argv[3], int(sys.argv[4]),
               sys.argv[5] == "die")
    else:
        main()
